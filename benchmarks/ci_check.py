"""One-shot CI gate: tier-1 tests + bench smokes + BENCH gate-field diffs.

Three stages, each skippable, all on by default:

1. **tier-1** — ``python -m pytest -x -q`` (the repo's correctness floor;
   ``tests/conftest.py`` auto-deselects the ``slow``/``soak`` markers, so
   this is exactly the default developer run).
2. **bench smokes** — every gated benchmark module in ``--smoke`` mode,
   writing JSON to a scratch directory (the checked-in ``BENCH_*.json``
   at the repo root are never touched).
3. **gate diffs** — the gate fields of the checked-in ``BENCH_*.json``
   are (a) re-validated against their hard gates and (b) printed next to
   the fresh smoke values so a drifting figure is visible in the CI log
   before it rots.  Smoke shapes are smaller than the committed full
   runs, so the diff is informational; the PASS/FAIL verdict comes from
   the gates on the committed files:

   * ``BENCH_spec.json`` — every row ``greedy_parity`` true,
     ``tokens_per_step >= 1`` (> 1 somewhere), and single-pass verify:
     ``target_passes_per_iter <= 1.25`` on every row;
   * ``BENCH_batching.json`` — continuous goodput >= 1.3x static on at
     least one cell, and every pooled-speculative cell commits
     ``goodput_tokens_per_iter`` in [1, spec_k + 1];
   * ``BENCH_loglinear.json`` — 32k-row state bytes <= 2x the ideal
     log2(N) bucket budget, multi-scale recall beats single-state lln
     (accuracy + cosine margin), chunked decode overhead <= 3x lln.

Usage:
    PYTHONPATH=src python -m benchmarks.ci_check [--no-tier1] \
        [--no-smoke] [--no-gates] [--keep PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: Gated bench modules run in --smoke mode (module name, output file).
SMOKES = (
    ("benchmarks.bench_spec", "BENCH_spec.json"),
    ("benchmarks.bench_batching", "BENCH_batching.json"),
    ("benchmarks.bench_serve", "BENCH_serve.json"),
    ("benchmarks.bench_dispatch", "BENCH_dispatch.json"),
    ("benchmarks.bench_robustness", "BENCH_robustness.json"),
    ("benchmarks.bench_longctx", "BENCH_longctx.json"),
    ("benchmarks.bench_loglinear", "BENCH_loglinear.json"),
)


def _env():
    env = dict(os.environ)
    src = os.path.join(ROOT, "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def run_tier1() -> bool:
    print("== tier-1: python -m pytest -x -q ==", flush=True)
    proc = subprocess.run([sys.executable, "-m", "pytest", "-x", "-q"],
                          cwd=ROOT, env=_env())
    return proc.returncode == 0


def run_smokes(out_dir: str) -> bool:
    ok = True
    for mod, fname in SMOKES:
        out = os.path.join(out_dir, fname)
        print(f"== smoke: python -m {mod} --smoke ==", flush=True)
        proc = subprocess.run(
            [sys.executable, "-m", mod, "--smoke", "--out", out],
            cwd=ROOT, env=_env())
        if proc.returncode != 0 or not os.path.exists(out):
            print(f"FAIL: {mod} (rc={proc.returncode})", flush=True)
            ok = False
    return ok


def _load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


def _spec_gates(report) -> list:
    fails = []
    rows = report.get("rows", [])
    for row in rows:
        if row.get("greedy_parity") is not True:
            fails.append(f"{row['name']}: greedy_parity != true")
        if not row.get("tokens_per_step", 0) >= 1.0:
            fails.append(f"{row['name']}: tokens_per_step < 1")
        tp = row.get("target_passes_per_iter")
        if tp is not None and not 1.0 <= tp <= 1.25:
            fails.append(f"{row['name']}: target_passes_per_iter {tp} "
                         "outside [1, 1.25]")
    if not any(r.get("tokens_per_step", 0) > 1.0 for r in rows):
        fails.append("no row with tokens_per_step > 1")
    return fails


def _batching_gates(report) -> list:
    fails = []
    rows = report.get("results", [])
    if not any(r.get("speedup", 0) >= 1.3 for r in rows):
        fails.append("no cell with continuous >= 1.3x static goodput")
    for row in rows:
        sp = row.get("continuous_spec")
        if not sp:
            continue
        g = sp.get("goodput_tokens_per_iter", 0)
        if not 1.0 <= g <= sp.get("spec_k", 0) + 1:
            fails.append(f"{row['name']}: spec goodput/iter {g} outside "
                         f"[1, spec_k + 1]")
    return fails


def _loglinear_gates(report) -> list:
    fails = []
    rows = {r.get("name"): r for r in report.get("results", [])}
    sb = rows.get("state_bytes")
    if sb is None:
        fails.append("missing state_bytes row")
    elif not sb.get("ratio_vs_ideal", 99.0) <= sb.get("gate_ratio", 2.0):
        fails.append(f"state_bytes: ratio_vs_ideal {sb['ratio_vs_ideal']} "
                     f"> {sb.get('gate_ratio')}")
    rc = rows.get("recall")
    if rc is None:
        fails.append("missing recall row")
    else:
        ml, ll = rc.get("log_linear", {}), rc.get("lln", {})
        if not ml.get("top1_acc", 0) >= rc.get("gate_acc", 0.85):
            fails.append(f"recall: log_linear acc {ml.get('top1_acc')} "
                         f"< {rc.get('gate_acc')}")
        if not ml.get("top1_acc", 0) >= ll.get("top1_acc", 1):
            fails.append("recall: log_linear acc below single-state lln")
        if not ml.get("cos_margin", -1) > ll.get("cos_margin", 1):
            fails.append("recall: log_linear cos margin not above lln")
    dc = rows.get("decode_cost")
    if dc is None:
        fails.append("missing decode_cost row")
    elif not dc.get("overhead_ratio", 99.0) <= dc.get("gate_ratio", 3.0):
        fails.append(f"decode_cost: overhead_ratio "
                     f"{dc['overhead_ratio']} > {dc.get('gate_ratio')}")
    return fails


def _gate_fields(fname, report) -> dict:
    """The gate-relevant scalars of a report, flattened for the diff."""
    out = {}
    if report is None:
        return out
    if fname == "BENCH_spec.json":
        for r in report.get("rows", []):
            out[f"{r['name']}.tokens_per_step"] = r.get("tokens_per_step")
            out[f"{r['name']}.target_passes_per_iter"] = \
                r.get("target_passes_per_iter")
    elif fname == "BENCH_batching.json":
        for r in report.get("results", []):
            out[f"{r['name']}.speedup"] = r.get("speedup")
            sp = r.get("continuous_spec") or {}
            out[f"{r['name']}.spec_goodput_per_iter"] = \
                sp.get("goodput_tokens_per_iter")
    elif fname == "BENCH_loglinear.json":
        for r in report.get("results", []):
            if r.get("name") == "state_bytes":
                out["state_bytes.ratio_vs_ideal"] = r.get("ratio_vs_ideal")
            elif r.get("name") == "recall":
                out["recall.log_linear_acc"] = \
                    r.get("log_linear", {}).get("top1_acc")
                out["recall.lln_acc"] = r.get("lln", {}).get("top1_acc")
            elif r.get("name") == "decode_cost":
                out["decode_cost.overhead_ratio"] = r.get("overhead_ratio")
    return out


def diff_gates(out_dir: str) -> bool:
    ok = True
    for fname, checker in (("BENCH_spec.json", _spec_gates),
                           ("BENCH_batching.json", _batching_gates),
                           ("BENCH_loglinear.json", _loglinear_gates)):
        committed = _load(os.path.join(ROOT, fname))
        if committed is None:
            print(f"FAIL: missing/unreadable {fname}", flush=True)
            ok = False
            continue
        fails = checker(committed)
        verdict = "PASS" if not fails else "FAIL"
        print(f"== gates: {fname}: {verdict} ==", flush=True)
        for msg in fails:
            print(f"  GATE FAIL: {msg}", flush=True)
        ok = ok and not fails
        smoke = _gate_fields(fname, _load(os.path.join(out_dir, fname)))
        for key, val in _gate_fields(fname, committed).items():
            sv = smoke.get(key)
            extra = "" if sv is None else f"   smoke {sv:.3g} (diff shape)"
            print(f"  {key:48s} committed {val:.3g}{extra}", flush=True)
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--no-tier1", action="store_true")
    ap.add_argument("--no-smoke", action="store_true")
    ap.add_argument("--no-gates", action="store_true")
    ap.add_argument("--keep", default=None,
                    help="directory for smoke JSON (default: tempdir)")
    args = ap.parse_args(argv)
    out_dir = args.keep or tempfile.mkdtemp(prefix="bench_smoke_")
    ok = True
    if not args.no_tier1:
        ok = run_tier1() and ok
    if not args.no_smoke:
        os.makedirs(out_dir, exist_ok=True)
        ok = run_smokes(out_dir) and ok
    if not args.no_gates:
        ok = diff_gates(out_dir) and ok
    print("ci_check:", "PASS" if ok else "FAIL", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
