"""Paper Fig. 8a / Table 1 proxy: LLN(+Diag) convergence vs Softmax
Attention on RoBERTa-style MLM pre-training (synthetic Markov corpus —
GLUE itself is not available offline; the tracked quantity is the paper's
own headline evidence, the loss-curve gap).

Also logs the moment-matched alpha/beta trajectory (Fig. 9 analog).

Derived metrics:
  * final-loss gap |LLN+Diag - SA| (paper: curves overlap);
  * final-loss gap |LLN - SA|;
  * mean alpha over training (paper: ~2.0-2.2).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.attention import batch_alpha_beta, AttnConfig
from repro.data.synthetic import mlm_batches
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _train_curve(cfg, steps, seed=0, lr=3e-3, batch=8, seq=128,
                 track_alpha=False):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    state = adamw_init(params)
    opt_cfg = AdamWConfig(weight_decay=0.01)

    @jax.jit
    def step_fn(params, state, b):
        loss, grads = jax.value_and_grad(model.loss)(params, b)
        params, state, _ = adamw_update(grads, state, params, lr, opt_cfg)
        return params, state, loss

    @jax.jit
    def alpha_of(params, b):
        # probe layer-0 q/k statistics -> the dynamic (alpha, beta)
        from repro.models.layers import apply_norm, dense, embed_lookup
        lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
        x = embed_lookup(params["embed"], b["inputs"], cfg.cdtype)
        h = apply_norm(lp["ln1"], x, cfg.norm)
        bq, n, _ = h.shape
        q = dense(lp["attn"]["q_w"], h, cfg.cdtype).reshape(
            bq, n, cfg.n_heads, cfg.hd)
        k = dense(lp["attn"]["k_w"], h, cfg.cdtype).reshape(
            bq, n, cfg.n_kv_heads, cfg.hd)
        a, b_ = batch_alpha_beta(q, k, AttnConfig())
        return jnp.mean(a), jnp.mean(b_)

    gen = mlm_batches(cfg.vocab, batch, seq, seed=0)
    losses, alphas = [], []
    for _ in range(steps):
        b = {k: jnp.asarray(v) for k, v in next(gen).items()}
        params, state, loss = step_fn(params, state, b)
        losses.append(float(loss))
        if track_alpha:
            a, bb = alpha_of(params, b)
            alphas.append((float(a), float(bb)))
    return np.asarray(losses), alphas


def run(steps: int = 60, verbose: bool = True):
    t0 = time.time()
    curves = {}
    alphas = None
    for impl in ("softmax", "lln", "lln_diag"):
        cfg = get_config("roberta-lln", smoke=True, attn_impl=impl)
        curves[impl], a = _train_curve(cfg, steps,
                                       track_alpha=(impl == "lln_diag"))
        if impl == "lln_diag":
            alphas = a
        if verbose:
            c = curves[impl]
            print(f"  {impl:9s} loss: {c[0]:.3f} -> {np.mean(c[-5:]):.3f}")
    dt_us = (time.time() - t0) * 1e6 / (3 * steps)
    gap_diag = float(np.abs(np.mean(curves['lln_diag'][-10:])
                            - np.mean(curves['softmax'][-10:])))
    gap_lln = float(np.abs(np.mean(curves['lln'][-10:])
                           - np.mean(curves['softmax'][-10:])))
    mean_alpha = float(np.mean([a for a, _ in alphas])) if alphas else -1
    if verbose and alphas:
        print(f"  fig9 alpha trajectory: start {alphas[0][0]:.2f} "
              f"end {alphas[-1][0]:.2f}")
    return [("fig8a_final_gap_lln_diag_vs_sa", dt_us, gap_diag),
            ("fig8a_final_gap_lln_vs_sa", dt_us, gap_lln),
            ("fig8a_sa_learned_delta", dt_us,
             float(curves['softmax'][0] - np.mean(curves['softmax'][-5:]))),
            ("fig9_mean_alpha", dt_us, mean_alpha)]


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
