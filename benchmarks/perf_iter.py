"""Perf-iteration runner: measure a config variant's roofline terms against
the baseline for one (arch x shape) cell.

  PYTHONPATH=src:. python -m benchmarks.perf_iter --arch yi-9b \
      --shape train_4k --variant castbf16 --override cast_params_once=True

Runs the cell's probe plan with the extra overrides (tagged by variant so
baseline probes are untouched), analyzes both, and prints the three-term
delta.  Results append to experiments/perf_log.json for EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import argparse
import json
import os

from .roofline import analyze_cell, probe_plan, run_probes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True)
    ap.add_argument("--override", default="")
    ap.add_argument("--attn-impl", default="auto")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--log", default="experiments/perf_log.json")
    ap.add_argument("--hypothesis", default="")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.launch.dryrun import parse_overrides
    overrides = parse_overrides(args.override)
    cfg = get_config(args.arch, **overrides)
    plan, _ = probe_plan(args.arch, cfg)

    # baseline probes (assumed present from the sweep; run if missing)
    base_cfg = get_config(args.arch)
    base_plan, _ = probe_plan(args.arch, base_cfg)
    run_probes(args.arch, args.shape, args.out, base_plan)
    run_probes(args.arch, args.shape, args.out, plan, variant=args.variant,
               extra=args.override, attn_impl=args.attn_impl)

    base = analyze_cell(args.arch, args.shape, args.out)
    var = analyze_cell(args.arch, args.shape, args.out, variant=args.variant,
                       extra_cfg=overrides,
                       attn_impl=None if args.attn_impl == "auto"
                       else args.attn_impl)
    if not base or not var:
        raise SystemExit("missing probes")

    print(f"{'term':14s} {'baseline':>12s} {'variant':>12s} {'delta':>8s}")
    deltas = {}
    for term in ("compute_s", "memory_s", "collective_s", "roofline_s"):
        b, v = base[term], var[term]
        d = (v - b) / b if b else 0.0
        deltas[term] = d
        print(f"{term:14s} {b:12.4f} {v:12.4f} {d:+8.1%}")
    print(f"dominant: {base['dominant']} -> {var['dominant']}")

    entry = {"arch": args.arch, "shape": args.shape,
             "variant": args.variant, "override": args.override,
             "attn_impl": args.attn_impl, "hypothesis": args.hypothesis,
             "baseline": {k: base[k] for k in
                          ("compute_s", "memory_s", "collective_s",
                           "dominant", "useful_ratio")},
             "result": {k: var[k] for k in
                        ("compute_s", "memory_s", "collective_s",
                         "dominant", "useful_ratio")},
             "deltas": deltas}
    log = []
    if os.path.exists(args.log):
        with open(args.log) as f:
            log = json.load(f)
    log.append(entry)
    with open(args.log, "w") as f:
        json.dump(log, f, indent=2)


if __name__ == "__main__":
    main()
