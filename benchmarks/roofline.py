"""Roofline analysis from compiled dry-run artifacts.

Method
------
XLA's HLO cost analysis counts a `while` (scan) body ONCE regardless of trip
count, so full-depth modules under-report FLOPs/bytes/collectives.  We
therefore reconstruct exact full-model numbers from *unrolled shallow
probes*: per (arch x shape), lower/compile the same global shapes at 1-2
layers with ``scan_unroll=True`` and combine linearly:

    full_metric = fixed + n_layers * marginal_per_layer

with family-appropriate probe plans (deepseek keeps its first dense layer;
zamba2 probes both the 6-layer shared-attention group and the bare mamba
layer; seamless separates encoder and decoder marginals).  Peak memory is
NOT linear, so memory_analysis comes from the full-depth scan dry-run.

Terms (single-pod 16x16 = 256 chips of TPU v5e):
    compute    = HLO_FLOPs / (chips * 197e12)          [bf16 peak]
    memory     = HLO_bytes / (chips * 819e9)           [HBM]
    collective = sum_ops per_device_bytes * ring_factor / 50e9 [ICI/link]
with ring factors: all-reduce 2x, all-gather/reduce-scatter 1x,
all-to-all 1/axis, collective-permute 1x.  (Cross-pod rows would use the
25 GB/s DCN figure; the roofline table is single-pod per the assignment.)

MODEL_FLOPS = 6 * N_active * tokens (train) / 2 * N_active * tokens
(prefill/decode), N_active = active matmul params per token (analytic,
per config — includes lm_head, excludes embedding gather).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

RING_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 0.25, "collective-permute": 1.0}

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


# ---------------------------------------------------------------------------
# Probe plans: list of (tag, overrides); combine(probes) -> full estimate.
# ---------------------------------------------------------------------------

def _linear_plan(l_small, l_big, n_layers, extra=""):
    # grad_accum=1 in probes: the accumulation scan's body would otherwise
    # be counted once (FLOPs are accum-invariant; memory comes from the
    # full-depth run anyway).
    base = "scan_unroll=True,grad_accum=1"
    ov = (lambda l: f"n_layers={l},{base}" + (("," + extra) if extra else ""))
    def combine(p):
        marg = {k: p[f"L{l_big}"][k] - p[f"L{l_small}"][k]
                for k in p[f"L{l_small}"]}
        fixed = {k: p[f"L{l_small}"][k] - l_small * marg[k]
                 for k in marg}
        return {k: fixed[k] + n_layers * marg[k] for k in marg}
    return [(f"L{l_small}", ov(l_small)), (f"L{l_big}", ov(l_big))], combine


def probe_plan(arch: str, cfg):
    if arch == "deepseek-v2-236b":
        # layer 0 is dense; marginal = one MoE layer
        return _linear_plan(2, 3, cfg.n_layers)
    if arch == "zamba2-7b":
        # group = 6 mamba + 1 shared-attn application; 81 = 13 groups + 3 tail
        probes = [("G1", "n_layers=6,scan_unroll=True,grad_accum=1"),
                  ("G2", "n_layers=12,scan_unroll=True,grad_accum=1"),
                  ("M1", "n_layers=1,shared_attn_period=0,"
                         "scan_unroll=True,grad_accum=1"),
                  ("M2", "n_layers=2,shared_attn_period=0,"
                         "scan_unroll=True,grad_accum=1")]

        def combine(p):
            group = {k: p["G2"][k] - p["G1"][k] for k in p["G1"]}
            mamba = {k: p["M2"][k] - p["M1"][k] for k in p["M1"]}
            fixed = {k: p["G1"][k] - group[k] for k in group}
            return {k: fixed[k] + 13 * group[k] + 3 * mamba[k]
                    for k in group}
        return probes, combine
    if arch == "seamless-m4t-medium":
        probes = [("A", "enc_layers=1,n_layers=1,scan_unroll=True,grad_accum=1"),
                  ("B", "enc_layers=2,n_layers=1,scan_unroll=True,grad_accum=1"),
                  ("C", "enc_layers=1,n_layers=2,scan_unroll=True,grad_accum=1")]

        def combine(p):
            enc = {k: p["B"][k] - p["A"][k] for k in p["A"]}
            dec = {k: p["C"][k] - p["A"][k] for k in p["A"]}
            fixed = {k: p["A"][k] - enc[k] - dec[k] for k in enc}
            return {k: fixed[k] + cfg.enc_layers * enc[k]
                    + cfg.n_layers * dec[k] for k in enc}
        return probes, combine
    return _linear_plan(1, 2, cfg.n_layers)


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS.
# ---------------------------------------------------------------------------

def active_params_per_token(cfg) -> float:
    d, f, hd = cfg.d_model, cfg.d_ff, cfg.hd
    h, g = cfg.n_heads, cfg.n_kv_heads
    glu = 3 if cfg.act.endswith("_glu") else 2

    def attn_params():
        if cfg.kv_lora:
            ql, kvl = cfg.q_lora, cfg.kv_lora
            nd, rd, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
            q = (d * ql + ql * h * (nd + rd)) if ql else d * h * (nd + rd)
            return (q + d * kvl + kvl * h * nd + kvl * h * vd + d * rd
                    + h * vd * d)
        return d * h * hd + 2 * d * g * hd + h * hd * d

    def mlp_dense(ff):
        return glu * d * ff

    def moe_active():
        return (d * cfg.n_experts                       # router
                + cfg.top_k * glu * d * cfg.expert_d_ff
                + cfg.n_shared_experts * glu * d * cfg.expert_d_ff)

    def ssm_params():
        di = cfg.ssm_expand * d
        gs = cfg.ssm_groups * cfg.ssm_state
        return 2 * d * di + 2 * d * gs + d * (di // cfg.ssm_head_dim) + di * d

    per_layer = 0.0
    if cfg.family in ("dense", "vlm", "encoder"):
        per_layer = cfg.n_layers * (attn_params() + mlp_dense(f))
    elif cfg.family == "moe":
        first = cfg.first_dense_layers
        per_layer = (first * (attn_params() + mlp_dense(f))
                     + (cfg.n_layers - first) * (attn_params() + moe_active()))
    elif cfg.family == "ssm":
        per_layer = cfg.n_layers * ssm_params()
    elif cfg.family == "hybrid":
        groups = (cfg.n_layers // cfg.shared_attn_period
                  if cfg.shared_attn_period else 0)
        per_layer = (cfg.n_layers * ssm_params()
                     + groups * (2 * d * d + attn_params() + mlp_dense(f)))
    elif cfg.family == "encdec":
        per_layer = (cfg.enc_layers * (attn_params() + mlp_dense(f))
                     + cfg.n_layers * (2 * attn_params() + mlp_dense(f)))
    head = d * cfg.padded_vocab
    return per_layer + head


def model_flops(cfg, shape) -> float:
    n_active = active_params_per_token(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # one decode token


# ---------------------------------------------------------------------------
# Analytic supplement for intra-layer scans.
#
# flash attention (kv x q chunk scans), the causal-LLN / SSD chunk scans and
# the chunked-xent scan are `lax.scan`s whose trip counts are NOT layer
# counts — the probe reconstruction cannot recover them, and unrolling a
# 32k/1024-step scan is not compilable.  Their FLOPs/bytes are exact,
# shape-derived quantities of our own implementations, added analytically.
# They contain no collectives (all resharding happens at the projections,
# which the probes DO count).
# ---------------------------------------------------------------------------

TRAIN_MULT = 4.0    # fwd + bwd(2x) + full-remat recompute (1x)
SERVE_MULT = 1.0


def _attn_divisor(cfg, shape, impl) -> float:
    """How many devices share the global attention work (see sharding.py)."""
    msize = 16
    batch_div = min(shape.global_batch, 16) if shape.global_batch > 1 else 1
    if cfg.attn_shard == "replicate":
        return batch_div * (msize if (shape.global_batch * shape.seq_len)
                            % (16 * msize) == 0 else 1)
    if impl in ("lln", "lln_diag") and cfg.attn_shard == "context":
        return batch_div                     # LLN replicated over model
    return batch_div * msize                 # heads- or seq-sharded


def attention_supplement(cfg, shape, impl) -> tuple[float, float]:
    """(flops, bytes) per DEVICE for the intra-layer attention scans (plus
    the chunked-xent tail).  Forward counts x train/serve multiplier."""
    bsz, n = shape.global_batch, shape.seq_len
    h, hd = cfg.n_heads, cfg.hd
    d_attn = (cfg.nope_head_dim + cfg.rope_head_dim) if cfg.kv_lora else hd
    dv = cfg.v_head_dim if cfg.kv_lora else hd
    bytes_el = 2.0                            # bf16 activations
    mult = TRAIN_MULT if shape.kind == "train" else SERVE_MULT

    def softmax_full(num_layers, n_q, n_k):
        # our flash computes every (q-block, kv-block) pair incl. masked
        f = num_layers * 4.0 * bsz * n_q * n_k * h * (d_attn + dv) / 2
        # kv re-read once per q-block (chunk 1024), q/o once
        nqc = max(n_q // 1024, 1)
        by = num_layers * bsz * h * bytes_el * (
            n_k * d_attn * 2 * nqc + n_q * (d_attn + dv))
        return f, by

    def lln_(num_layers, n_):
        c = cfg.lln_chunk
        f = num_layers * bsz * n_ * h * (
            2 * c * (d_attn + dv) + 6 * d_attn * dv)
        by = num_layers * bsz * h * bytes_el * 3 * n_ * d_attn
        if impl == "lln_diag":
            f += num_layers * 4.0 * bsz * n_ * cfg.diag_block * h * \
                (d_attn + dv) / 2
            by *= 2
        return f, by

    def ssd_(num_layers, n_):
        di = cfg.ssm_expand * cfg.d_model
        hh = di // cfg.ssm_head_dim
        c, s, pdim = cfg.ssm_chunk, cfg.ssm_state, cfg.ssm_head_dim
        f = num_layers * 2.0 * bsz * n_ * hh * (c * s + c * pdim
                                                + 2 * s * pdim)
        by = num_layers * bsz * n_ * hh * bytes_el * 2 * (pdim + 2 * s)
        return f, by

    def decode_softmax(num_layers, ctx):
        f = num_layers * 4.0 * bsz * ctx * h * (d_attn + dv) / 2
        by = num_layers * bsz * ctx * cfg.n_kv_heads * d_attn * 2 * bytes_el
        if cfg.kv_lora:   # absorbed MLA: latent-space scores + context
            f = num_layers * 4.0 * bsz * ctx * h * cfg.kv_lora
            by = num_layers * bsz * ctx * cfg.kv_lora * bytes_el
        return f, by

    def decode_lln(num_layers):
        f = num_layers * bsz * h * (6 * d_attn * dv
                                    + 4 * cfg.diag_block * (d_attn + dv) / 2)
        by = num_layers * bsz * h * d_attn * dv * 4.0   # fp32 state
        return f, by

    fl, by = 0.0, 0.0
    if shape.kind in ("train", "prefill"):
        if cfg.family == "ssm":
            fl, by = ssd_(cfg.n_layers, n)
        elif cfg.family == "hybrid":
            fl, by = ssd_(cfg.n_layers, n)
            groups = cfg.n_layers // max(cfg.shared_attn_period, 1)
            f2, b2 = (lln_(groups, n) if impl in ("lln", "lln_diag")
                      else softmax_full(groups, n, n))
            fl, by = fl + f2, by + b2
        elif cfg.family == "encdec":
            if impl in ("lln", "lln_diag"):
                fe, be = lln_(cfg.enc_layers, n)
                fd, bd = lln_(cfg.n_layers, n)
            else:
                fe, be = softmax_full(cfg.enc_layers, n, n)
                fd, bd = softmax_full(cfg.n_layers, n, n)
            fx, bx = softmax_full(cfg.n_layers, n, n)   # cross attention
            fl, by = fe + fd + fx, be + bd + bx
        else:
            fl, by = (lln_(cfg.n_layers, n) if impl in ("lln", "lln_diag")
                      else softmax_full(cfg.n_layers, n, n))
    else:  # decode
        if cfg.family == "ssm":
            fl, by = ssd_(cfg.n_layers, 1)
        elif cfg.family == "hybrid":
            fl, by = ssd_(cfg.n_layers, 1)
            groups = cfg.n_layers // max(cfg.shared_attn_period, 1)
            f2, b2 = (decode_lln(groups) if impl in ("lln", "lln_diag")
                      else decode_softmax(groups, n))
            fl, by = fl + f2, by + b2
        else:
            layers = cfg.n_layers + (cfg.enc_layers
                                     if cfg.family == "encdec" else 0) * 0
            fl, by = (decode_lln(layers) if impl in ("lln", "lln_diag")
                      else decode_softmax(layers, n))
            if cfg.family == "encdec":   # cross-attention over the memory
                f2, b2 = decode_softmax(cfg.n_layers, n)
                fl, by = fl + f2, by + b2

    # chunked-xent tail (vocab matmul beyond the single probe-counted chunk)
    if shape.kind == "train":
        tokens = bsz * n
        fl += 2.0 * tokens * cfg.d_model * cfg.padded_vocab * \
            (TRAIN_MULT - 1) / TRAIN_MULT  # probe counted ~one fwd chunk
    div = _attn_divisor(cfg, shape, impl)
    return fl * mult / div, by * mult / div


# ---------------------------------------------------------------------------
# Assembly.
# ---------------------------------------------------------------------------

def _metrics_of(result: dict) -> dict:
    m = {"flops": result.get("flops", 0.0),
         "bytes": result.get("bytes_accessed", 0.0)}
    for op, rec in (result.get("collectives") or {}).items():
        m[f"coll_{op}"] = float(rec["bytes"])
        m[f"cnt_{op}"] = float(rec["count"])
    return m


def _metric_keys(probes: dict) -> set:
    keys = set()
    for p in probes.values():
        keys |= set(p)
    return keys


def load_cell(out_dir, arch, shape, tag):
    path = os.path.join(out_dir, f"{arch}__{shape}__{tag}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def run_probes(arch, shape, out_dir, plan, *, variant="", extra="",
               attn_impl="auto"):
    pre = f"p{variant}_" if variant else "p"
    for tag, override in plan:
        path = os.path.join(out_dir, f"{arch}__{shape}__16x16__{pre}{tag}.json")
        if os.path.exists(path):
            continue
        ov = override + ("," + extra if extra else "")
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--out", out_dir, "--override", ov,
               "--tag", f"{pre}{tag}", "--attn-impl", attn_impl]
        print("[probe]", arch, shape, variant or "base", tag, flush=True)
        subprocess.run(cmd, check=False)


def analyze_cell(arch, shape_name, out_dir, *, variant="", extra_cfg=None,
                 attn_impl=None):
    from repro.configs import SHAPES_BY_NAME, get_config
    cfg = get_config(arch, **(extra_cfg or {}))
    shape = SHAPES_BY_NAME[shape_name]
    plan, combine = probe_plan(arch, cfg)
    pre = f"p{variant}_" if variant else "p"
    probes = {}
    for tag, _ in plan:
        r = load_cell(out_dir, arch, shape_name, f"16x16__{pre}{tag}")
        if r is None or not r.get("ok"):
            return None
        probes[tag] = _metrics_of(r)
    keys = _metric_keys(probes)
    for p in probes.values():
        for k in keys:
            p.setdefault(k, 0.0)
    full = combine(probes)

    base = load_cell(out_dir, arch, shape_name, "16x16") or {}
    impl = attn_impl or base.get("attn_impl", cfg.attn_impl)
    sup_f, sup_b = attention_supplement(cfg, shape, impl)
    flops_dev = max(full["flops"], 0.0) + sup_f
    bytes_dev = max(full["bytes"], 0.0) + sup_b
    coll_s = 0.0
    coll_detail = {}
    for op, fac in RING_FACTOR.items():
        b = max(full.get(f"coll_{op}", 0.0), 0.0)
        coll_detail[op] = b
        coll_s += b * fac / ICI_BW
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_total = flops_dev * CHIPS
    result = {
        "arch": arch, "shape": shape_name,
        "attn_impl": impl,
        "attn_supplement_flops": sup_f,
        "attn_supplement_bytes": sup_b,
        **{k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant.replace("_s", ""),
        "model_flops": mf,
        "hlo_flops_total": hlo_total,
        "useful_ratio": round(mf / hlo_total, 4) if hlo_total else None,
        "collective_bytes_per_dev": coll_detail,
        "temp_bytes_full": base.get("temp_size_in_bytes"),
        "arg_bytes_full": base.get("argument_size_in_bytes"),
        "roofline_s": round(max(terms.values()), 6),
    }
    best = max(terms.values())
    result["bound_fraction"] = {
        k.replace("_s", ""): round(v / best, 3) for k, v in terms.items()}
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--probes", action="store_true",
                    help="run missing probe dry-runs (subprocesses)")
    ap.add_argument("--archs", default="")
    ap.add_argument("--shapes", default=",".join(SHAPES))
    ap.add_argument("--report", default="experiments/roofline.json")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.registry import ASSIGNED_ARCHS
    archs = args.archs.split(",") if args.archs else list(ASSIGNED_ARCHS)
    shapes = args.shapes.split(",")

    if args.probes:
        for arch in archs:
            cfg = get_config(arch)
            plan, _ = probe_plan(arch, cfg)
            for shape in shapes:
                run_probes(arch, shape, args.out, plan)

    rows = []
    for arch in archs:
        for shape in shapes:
            r = analyze_cell(arch, shape, args.out)
            if r:
                rows.append(r)
            else:
                rows.append({"arch": arch, "shape": shape,
                             "error": "missing probes"})
    os.makedirs(os.path.dirname(args.report), exist_ok=True)
    with open(args.report, "w") as f:
        json.dump(rows, f, indent=2)

    hdr = (f"{'arch':24s} {'shape':12s} {'impl':9s} {'compute_s':>10s} "
           f"{'memory_s':>10s} {'coll_s':>10s} {'bound':>9s} {'useful':>7s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if "error" in r:
            print(f"{r['arch']:24s} {r['shape']:12s} -- {r['error']}")
            continue
        print(f"{r['arch']:24s} {r['shape']:12s} {r['attn_impl']:9s} "
              f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} "
              f"{r['collective_s']:10.4f} {r['dominant']:>9s} "
              f"{r['useful_ratio'] if r['useful_ratio'] else 0:7.3f}")


if __name__ == "__main__":
    main()
