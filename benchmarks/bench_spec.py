"""Speculative-decode bench: tokens/verify-step and acceptance across
k × impl × r.

Each cell runs greedy draft-then-verify generation
(``launch/steps.py:make_spec_setup`` — tied first-``draft_layers`` draft,
chunked target verify, per-row partial commit) for ``steps`` tokens per
row and reports:

* ``acceptance_rate`` — accepted drafts / drafted tokens;
* ``tokens_per_step`` — committed tokens per verify iteration (the
  sequential-dependency win; 1.0 is the non-speculative loop, k+1 the
  ceiling).  This is the gated figure: > 1 whenever any draft survives;
* ``target_passes_per_iter`` — FULL target-transformer passes traced per
  verify iteration (``models/transformer.py:DECODE_PASS_COUNTS``; the
  jitted loop's scan body traces exactly once, so the trace count IS the
  per-iteration dispatch count).  Single-pass verify holds this at 1:
  the score pass returns per-layer k/v residuals and the accepted prefix
  is folded with the O(T d^2) ``lm_commit`` einsum instead of a second
  pass.  Gated <= 1.25 by tests/test_bench_spec.py;
* ``spec_tok_s`` / ``base_tok_s`` — wall-clock tokens/s of the
  speculative loop vs the non-speculative scanned loop on the same
  shape (AOT-compiled, compile excluded; the timed scan is right-sized
  to the iterations the run actually needs, discovered by an untimed
  worst-case probe — greedy decoding is deterministic, so both runs
  commit identical tokens).  On this CPU container the verify pass
  costs ~2 target dispatches (score + commit) and the draft is a large
  fraction of the tiny target, so wall-clock parity is out of reach;
  tokens/step is the hardware-independent metric.

CSV rows follow the repo convention (name, us_per_call, derived) with
``us_per_call`` = wall-us per committed token and ``derived`` =
tokens_per_step.  Writes ``BENCH_spec.json`` at the repo root
(schema: benchmarks/README.md).

Usage:
    PYTHONPATH=src python -m benchmarks.bench_spec [--smoke] [--out PATH]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeSpec
from repro.launch.mesh import compat_mesh
from repro.launch.steps import (flatten_spec_tokens, make_serve_setup,
                                make_spec_setup)
from repro.models import build_model, synthetic_batch
from repro.models.transformer import DECODE_PASS_COUNTS

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_spec.json")


def _cfg(impl: str, r: int, n_layers: int) -> ArchConfig:
    h = 4
    return ArchConfig(
        name=f"bench-spec-{impl}-r{r}", family="dense", n_layers=n_layers,
        d_model=64, n_heads=h, n_kv_heads=h // r, d_ff=128, vocab=256,
        head_dim=16, attn_impl=impl, diag_block=8, lln_chunk=8,
        softmax_chunk=32, lln_fixed_ab=2.1 if impl != "softmax" else 0.0,
        compute_dtype="float32", param_dtype="float32", remat="none",
        tie_embeddings=True)


def _cell(impl: str, r: int, k: int, draft_layers: int, *, batch: int,
          prompt: int, steps: int, n_layers: int):
    cfg = _cfg(impl, r, n_layers)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = prompt + steps + k + 2
    mesh = compat_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("spec", max_len, batch, "decode")
    batch_in = synthetic_batch(cfg, batch, max_len, text_seq=prompt)
    with mesh:
        # Non-speculative baseline: the scanned generation loop.
        serve = make_serve_setup(cfg, shape, mesh, multi_pod=False)
        logits, caches = serve.prefill_fn(params, batch_in)
        tok0 = jnp.argmax(logits[:, -1] if logits.ndim == 3 else logits,
                          -1).astype(jnp.int32)
        pos0 = jnp.asarray(prompt, jnp.int32)
        key = jax.random.PRNGKey(1)
        base = serve.make_generate(steps, 0.0)
        base = base.lower(params, caches, tok0, pos0, key).compile()
        t0 = time.perf_counter()
        ref_toks, _ = base(params, caches, tok0, pos0, key)
        jax.block_until_ready(ref_toks)
        t_base = time.perf_counter() - t0

        # Speculative loop on the same shape.  Discovery pass first: run
        # the worst-case-length scan (iters = steps) untimed to learn how
        # many verify iterations this (deterministic, greedy) run really
        # needs, then TIME a right-sized scan — a fixed worst-case scan
        # would keep paying full draft+verify cost for dead iterations
        # after every row has finished, turning wall-clock into an
        # artifact of the scan length rather than of speculation.
        spec = make_spec_setup(cfg, shape, mesh, spec_k=k,
                               draft_layers=draft_layers)
        lg, tc, dc = spec.prefill_fn(params, batch_in)
        tok0s = jnp.argmax(lg[:, -1] if lg.ndim == 3 else lg,
                           -1).astype(jnp.int32)
        probe = spec.make_generate(steps)
        toks, n_emit, n_acc, live, *_ = jax.block_until_ready(
            probe(params, tc, dc, tok0s, pos0, key))
        n_emit_h = np.asarray(n_emit)
        iters_used = [int(np.argmax(np.cumsum(n_emit_h[b_]) >= steps)) + 1
                      for b_ in range(batch)]
        lg, tc, dc = spec.prefill_fn(params, batch_in)   # fresh caches
        gen = spec.make_generate(steps, iters=max(iters_used))
        # Trace-time dispatch audit: lowering traces the scan body once,
        # so the counter delta is full target passes PER verify iteration
        # (score counts; the O(T d^2) residual commit does not).
        DECODE_PASS_COUNTS.clear()
        lowered = gen.lower(params, tc, dc, tok0s, pos0, key)
        target_passes = DECODE_PASS_COUNTS.get(cfg.name, 0)
        draft_passes = DECODE_PASS_COUNTS.get(f"{cfg.name}-draft"
                                              f"{draft_layers}", 0)
        gen = lowered.compile()
        t0 = time.perf_counter()
        toks, n_emit, n_acc, live, *_ = gen(params, tc, dc, tok0s, pos0,
                                            key)
        jax.block_until_ready(toks)
        t_spec = time.perf_counter() - t0

    flat = flatten_spec_tokens(toks, n_emit, steps)
    parity = bool(np.array_equal(flat, np.asarray(ref_toks)))
    n_acc_h, live_h = np.asarray(n_acc), np.asarray(live)
    drafted = float(live_h.sum() * k)
    acc_rate = float(n_acc_h.sum()) / max(drafted, 1.0)
    tokens_per_step = float(np.mean([steps / i for i in iters_used]))
    total = steps * batch
    return {
        "name": f"spec_{impl}_r{r}_k{k}_dl{draft_layers}",
        "us_per_call": t_spec * 1e6 / total,
        "acceptance_rate": acc_rate,
        "tokens_per_step": tokens_per_step,
        "target_passes_per_iter": float(target_passes),
        "draft_passes_per_iter": float(draft_passes),
        "spec_tok_s": total / max(t_spec, 1e-9),
        "base_tok_s": total / max(t_base, 1e-9),
        "speedup_vs_base": t_base / max(t_spec, 1e-9),
        "greedy_parity": parity,
    }


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        verbose: bool = True):
    batch, prompt = 2, 16
    if smoke:
        steps, n_layers = 8, 2
        cells = [("lln_diag", 1, 2, 2), ("softmax", 1, 2, 1)]
    else:
        steps, n_layers = 24, 2
        cells = [(impl, r, k, dl)
                 for impl in ("softmax", "lln", "lln_diag")
                 for r in (1, 4)
                 for k, dl in ((2, 1), (4, 2))]
    rows = []
    for impl, r, k, dl in cells:
        rows.append(_cell(impl, r, k, dl, batch=batch, prompt=prompt,
                          steps=steps, n_layers=n_layers))
        if verbose:
            c = rows[-1]
            print(f"  {c['name']:32s} acc {c['acceptance_rate']:.2f}  "
                  f"tok/step {c['tokens_per_step']:.2f}  "
                  f"tgt-passes/iter {c['target_passes_per_iter']:.0f}  "
                  f"parity {c['greedy_parity']}")
    report = {
        "host_backend": jax.default_backend(),
        "shape": {"batch": batch, "prompt": prompt, "steps": steps,
                  "n_layers": n_layers},
        "rows": rows,
    }
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
    return [(c["name"], c["us_per_call"], c["tokens_per_step"])
            for c in rows]


def run_rows(verbose: bool = True):
    """benchmarks/run.py adapter (no JSON write in the aggregate pass)."""
    return run(out_path="", smoke=True, verbose=verbose)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args(argv)
    run(out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
