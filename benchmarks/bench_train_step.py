"""Train-step benchmark: fwd vs fwd+bwd per attention impl and VJP path.

Times one jit'd training step — attention layer forward, backward and an
AdamW update — for each LLN attention entry point, comparing the two
backward implementations behind the same ``custom_vjp``:

* ``jnp_fallback`` — Pallas forward, legacy ``jax.vjp``-through-the-
  reference backward (``pallas_bwd=False``; the pre-fusion behaviour, kept
  as the ragged-length fallback);
* ``pallas_vjp``   — the fused-VJP path (default): Pallas backward kernels
  on compiled backends, their chunked ``lax.scan`` twins under interpret
  mode (see ``kernels/lln_backward.py``).  Either way the backward reuses
  the saved forward residuals instead of recomputing the forward.

Writes ``BENCH_train_step.json`` at the repo root (see benchmarks/README.md
for the schema).  Runs on whatever backend JAX selects — on the CPU
container the kernels execute in interpret mode, so absolute numbers are
only meaningful relative to each other on the same host.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_train_step [--smoke] \
        [--out PATH] [--repeats K]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_train_step.json")

IMPLS = ("lln_causal", "lln_bidir", "lln_diag")


@dataclasses.dataclass(frozen=True)
class Shape:
    b: int
    n: int
    h: int
    g: int
    d: int
    e: int
    chunk: int

    @property
    def name(self) -> str:
        return (f"b{self.b}_n{self.n}_h{self.h}_g{self.g}"
                f"_d{self.d}_c{self.chunk}")


SHAPES = [
    Shape(b=1, n=512, h=8, g=2, d=64, e=128, chunk=128),
    Shape(b=2, n=512, h=8, g=2, d=64, e=128, chunk=128),
    Shape(b=1, n=1024, h=8, g=2, d=64, e=128, chunk=128),
]
SMOKE_SHAPES = [Shape(b=1, n=64, h=2, g=1, d=8, e=16, chunk=32)]


def _attn(impl: str, q, k, v, alpha, beta, chunk: int, pallas_bwd: bool):
    if impl == "lln_causal":
        return kops.lln_attention(q, k, v, alpha, beta, True, chunk, None,
                                  pallas_bwd)
    if impl == "lln_bidir":
        return kops.lln_attention(q, k, v, alpha, beta, False, chunk, None,
                                  pallas_bwd)
    if impl == "lln_diag":
        return kops.lln_diag_attention(q, k, v, alpha, beta, True, chunk,
                                       None, pallas_bwd)
    raise ValueError(impl)


def _make_problem(shape: Shape, seed: int = 0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (shape.b, shape.n, shape.e))
    y = jax.random.normal(ks[1], (shape.b, shape.n, shape.e))
    params = {
        "wq": jax.random.normal(ks[2], (shape.e, shape.h * shape.d)) * 0.05,
        "wk": jax.random.normal(ks[3], (shape.e, shape.g * shape.d)) * 0.05,
        "wv": jax.random.normal(ks[4], (shape.e, shape.g * shape.d)) * 0.05,
        "wo": jax.random.normal(ks[5], (shape.h * shape.d, shape.e)) * 0.05,
    }
    alpha = jnp.full((shape.h,), 1.2)
    beta = jnp.full((shape.g,), 1.0)
    return x, y, params, alpha, beta


def _loss_fn(impl: str, shape: Shape, pallas_bwd: bool, alpha, beta):
    def loss(params, x, y):
        b, n = x.shape[:2]
        q = (x @ params["wq"]).reshape(b, n, shape.h, shape.d)
        k = (x @ params["wk"]).reshape(b, n, shape.g, shape.d)
        v = (x @ params["wv"]).reshape(b, n, shape.g, shape.d)
        out = _attn(impl, q, k, v, alpha, beta, shape.chunk, pallas_bwd)
        pred = out.reshape(b, n, shape.h * shape.d) @ params["wo"]
        return jnp.mean((pred - y) ** 2)
    return loss


def _time_interleaved(fns_args: list, repeats: int = 7) -> list:
    """Min wall time in microseconds for each (fn, args) pair.

    All candidates are warmed first (compile excluded), then the timed
    rounds interleave the candidates so host-load drift hits every path
    equally; min-of-rounds is the standard low-variance estimator for a
    deterministic jit'd step on a noisy container."""
    for fn, args in fns_args:
        jax.block_until_ready(fn(*args))
    samples = [[] for _ in fns_args]
    for _ in range(repeats):
        for i, (fn, args) in enumerate(fns_args):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            samples[i].append((time.perf_counter() - t0) * 1e6)
    return [min(s) for s in samples]


def bench_shape(shape: Shape, repeats: int) -> dict:
    x, y, params, alpha, beta = _make_problem(shape)
    opt_state = adamw_init(params)
    cfg = AdamWConfig()
    row: dict = {"shape": dataclasses.asdict(shape)}
    for impl in IMPLS:
        fwd = jax.jit(_loss_fn(impl, shape, True, alpha, beta))
        steps = {}
        for mode, pallas_bwd in (("jnp_fallback", False),
                                 ("pallas_vjp", True)):
            loss = _loss_fn(impl, shape, pallas_bwd, alpha, beta)

            @jax.jit
            def step(params, opt_state, x, y, loss=loss):
                g = jax.grad(loss)(params, x, y)
                return adamw_update(g, opt_state, params, 1e-3, cfg)

            steps[mode] = step
        fwd_us, jnp_us, pallas_us = _time_interleaved(
            [(fwd, (params, x, y)),
             (steps["jnp_fallback"], (params, opt_state, x, y)),
             (steps["pallas_vjp"], (params, opt_state, x, y))],
            repeats=repeats)
        row[impl] = {
            "fwd_us": fwd_us,
            "fwd_bwd_us": {"jnp_fallback": jnp_us, "pallas_vjp": pallas_us},
            "bwd_speedup": jnp_us / pallas_us,
        }
    return row


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        repeats: int = 7, verbose: bool = True) -> dict:
    shapes = SMOKE_SHAPES if smoke else SHAPES
    rows = []
    for shape in shapes:
        if verbose:
            print(f"== {shape.name} ==", flush=True)
        row = bench_shape(shape, repeats)
        rows.append({"name": shape.name, **row})
        if verbose:
            for impl in IMPLS:
                e = row[impl]
                print(f"  {impl:11s} fwd {e['fwd_us']:9.0f}us   "
                      f"fwd+bwd jnp {e['fwd_bwd_us']['jnp_fallback']:9.0f}us"
                      f" -> pallas {e['fwd_bwd_us']['pallas_vjp']:9.0f}us"
                      f"  ({e['bwd_speedup']:.2f}x)", flush=True)
    report = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() == "cpu",
        "repeats": repeats,
        "modes": {
            "jnp_fallback": "Pallas forward, legacy jax.vjp reference "
                            "backward (pallas_bwd=False)",
            "pallas_vjp": "fused VJP: Pallas backward kernels (compiled) / "
                          "their lax.scan twins (interpret), reusing saved "
                          "forward residuals (default)",
        },
        "results": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if verbose:
        print(f"wrote {out_path}")
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--repeats", type=int, default=7)
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny shape (CI)")
    args = ap.parse_args()
    run(args.out, smoke=args.smoke, repeats=args.repeats)


if __name__ == "__main__":
    main()
