"""Paper Table 2 (+ LRA Table 4) analog: time and memory scaling of
SA vs Nystromformer-class alternatives vs LLN vs LLN+Diag with sequence
length.

On this CPU container we measure wall-clock of jitted forward+backward at
growing N (fixed width), fit the complexity exponent b in t = a*N^b, and
compute the analytic attention-memory footprint per token.  The paper's
claims: LLN time/memory scale ~linearly (b ~= 1), SA quadratically
(b ~= 2), LLN handles >= 4x longer sequences at equal memory.

Derived metrics: fitted exponents and the peak-scores-bytes ratio at the
longest measured N.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AttnConfig, multi_head_attention


def _make_fn(impl, causal=True):
    cfg = AttnConfig(impl=impl, causal=causal, diag_block=64, lln_chunk=64,
                     softmax_chunk=64, fixed_ab=2.0)

    def loss(q, k, v):
        return jnp.sum(multi_head_attention(q, k, v, cfg) ** 2)
    return jax.jit(jax.grad(loss))


def _time_one(fn, q, k, v, iters=3):
    fn(q, k, v).block_until_ready()          # compile + warmup
    t0 = time.time()
    for _ in range(iters):
        fn(q, k, v).block_until_ready()
    return (time.time() - t0) / iters


def _fit_exponent(ns, ts):
    return float(np.polyfit(np.log(ns), np.log(ts), 1)[0])


def analytic_scores_bytes(impl, n, h=4, d=32, blk=64):
    """Live attention-intermediate bytes (fp32) per batch element."""
    if impl == "softmax":
        return n * n * h * 4                       # full score matrix class
    if impl == "lln":
        return (n * blk + d * d) * h * 4           # chunk scores + state
    return (n * blk + d * d + n * blk) * h * 4     # + diag blocks


def run(verbose: bool = True):
    key = jax.random.PRNGKey(0)
    ns = [256, 512, 1024, 2048]
    b, h, d = 1, 4, 32
    rows = []
    times = {}
    t_start = time.time()
    for impl in ("softmax", "lln", "lln_diag"):
        fn = _make_fn(impl)
        ts = []
        for n in ns:
            kq, kk, kv = jax.random.split(jax.random.fold_in(key, n), 3)
            q = jax.random.normal(kq, (b, n, h, d))
            k = jax.random.normal(kk, (b, n, h, d))
            v = jax.random.normal(kv, (b, n, h, d))
            ts.append(_time_one(fn, q, k, v))
        times[impl] = ts
        expo = _fit_exponent(ns, ts)
        rows.append((f"table2_time_exponent_{impl}",
                     ts[-1] * 1e6, expo))
        if verbose:
            print(f"  {impl:9s} t(N): " +
                  "  ".join(f"{t * 1e3:8.1f}ms" for t in ts) +
                  f"   exponent={expo:.2f}")
    # memory scaling (analytic live-intermediates, validated vs kernels)
    for impl in ("softmax", "lln", "lln_diag"):
        mem = [analytic_scores_bytes(impl, n) for n in ns]
        expo = _fit_exponent(ns, mem)
        rows.append((f"table2_mem_exponent_{impl}", 0.0, expo))
    # paper claim: at equal budget LLN reaches >= 4x longer sequences
    sm_mem = analytic_scores_bytes("softmax", 8192)
    n_reach = 8192
    while analytic_scores_bytes("lln", n_reach * 2) <= sm_mem:
        n_reach *= 2
        if n_reach > 8192 * 1024:
            break
    rows.append(("table2_lln_seq_reach_vs_sa_8k", 0.0,
                 float(n_reach / 8192)))
    if verbose:
        print(f"  at SA@8k memory budget, LLN reaches N={n_reach} "
              f"({n_reach / 8192:.0f}x)")
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
