"""Paper Fig. 2: entropy and spectral gap vs temperature for attention
kernels — SA, LLN (moment-matched), LLN (unmatched), ReLU kernel,
quadratic kernel.

The paper's claim: only the moment-matched LLN tracks SA's entropy and
spectral-gap curves; ReLU/quadratic kernels are temperature-indifferent.
Derived metrics: mean |entropy gap| to SA per kernel, and the entropy
dynamic range (max-min over the sigma sweep) — near-zero range reproduces
the "indifferent to temperature" observation.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics as M
from repro.core import moment_matching as mm


def _kernel_matrix(kind, q, k, sig, d):
    if kind == "softmax":
        return mm.softmax_attn_matrix(q, k)
    if kind == "lln_matched":
        a, b = mm.constants_for_dim(d)
        alpha, beta = mm.solve_alpha_beta(sig, sig, a, b)
        return mm.lln_attn_matrix(q, k, float(alpha), float(beta))
    if kind == "lln_unmatched":
        return mm.lln_attn_matrix(q, k, 1.0, 1.0)
    if kind == "relu":
        s = jax.nn.relu(q @ k.T)
        return s / (jnp.sum(s, -1, keepdims=True) + 1e-9)
    if kind == "quadratic":
        s = jnp.square(q @ k.T)
        return s / (jnp.sum(s, -1, keepdims=True) + 1e-9)
    raise ValueError(kind)


def run(n: int = 256, d: int = 64, seed: int = 0, verbose: bool = True):
    key = jax.random.PRNGKey(seed)
    sigmas = np.asarray([0.6, 0.8, 1.0, 1.3, 1.6])
    kinds = ("softmax", "lln_matched", "lln_unmatched", "relu", "quadratic")
    ent = {k: [] for k in kinds}
    gap = {k: [] for k in kinds}
    t0 = time.time()
    for sig in sigmas:
        kq, kk = jax.random.split(jax.random.fold_in(key, int(sig * 100)))
        q = float(sig) * jax.random.normal(kq, (n, d))
        k = float(sig) * jax.random.normal(kk, (n, d))
        for kind in kinds:
            p = _kernel_matrix(kind, q, k, float(sig), d)
            ent[kind].append(float(M.row_entropy(p)))
            gap[kind].append(M.spectral_gap(np.asarray(p, np.float64)))
    dt_us = (time.time() - t0) * 1e6 / (len(sigmas) * len(kinds))
    if verbose:
        print("      sigma:", "  ".join(f"{s:6.2f}" for s in sigmas))
        for kind in kinds:
            print(f"  H[{kind:13s}]:",
                  "  ".join(f"{e:6.2f}" for e in ent[kind]))
        for kind in kinds:
            print(f"  G[{kind:13s}]:",
                  "  ".join(f"{g:6.3f}" for g in gap[kind]))

    rows = []
    sm_e = np.asarray(ent["softmax"])
    sm_g = np.asarray(gap["softmax"])
    for kind in kinds[1:]:
        rows.append((f"fig2_entropy_gap_{kind}", dt_us,
                     float(np.abs(np.asarray(ent[kind]) - sm_e).mean())))
        rows.append((f"fig2_specgap_gap_{kind}", dt_us,
                     float(np.abs(np.asarray(gap[kind]) - sm_g).mean())))
    # temperature responsiveness (dynamic range of entropy over the sweep)
    for kind in kinds:
        rows.append((f"fig2_entropy_range_{kind}", dt_us,
                     float(sm_e.max() - sm_e.min()) if kind == "softmax"
                     else float(np.ptp(np.asarray(ent[kind])))))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
