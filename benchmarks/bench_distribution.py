"""Paper Figs. 5 & 7 / Props. 3.1 & 4.1: distribution of the attention
matrix.

Measures, over a sigma sweep:
  * Var[ln P^(SM)] vs the theoretical sigma_q^2 sigma_k^2 (Fig. 5a);
  * log-normality QQ-correlation of P^(SM) and P^(LLN) (Prop 3.1/4.1);
  * Var[ln P^(LLN)] before (alpha=beta=1) and after moment matching vs
    Var[ln P^(SM)] (Fig. 5b / Fig. 7).

Output CSV: name,us_per_call,derived  (derived = the headline metric).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import metrics as M
from repro.core import moment_matching as mm


def run(n: int = 1024, d: int = 64, seed: int = 0, verbose: bool = True):
    key = jax.random.PRNGKey(seed)
    rows = []
    sigmas = (0.8, 1.0, 1.2, 1.5)
    rel_errs, qq_sm, qq_lln, match_errs, raw_ratio = [], [], [], [], []
    t0 = time.time()
    for sig in sigmas:
        kq, kk = jax.random.split(jax.random.fold_in(key, int(sig * 100)))
        q = sig * jax.random.normal(kq, (n, d))
        k = sig * jax.random.normal(kk, (n, d))
        p_sm = mm.softmax_attn_matrix(q, k)
        _, var_sm = M.attention_log_moments(p_sm)
        var_sm = float(var_sm)
        theory = sig ** 4
        rel_errs.append(abs(var_sm - theory) / theory)
        qq_sm.append(M.lognormality_score(p_sm))

        a, b = mm.constants_for_dim(d)
        alpha, beta = mm.solve_alpha_beta(sig, sig, a, b)
        p_lln = mm.lln_attn_matrix(q, k, float(alpha), float(beta))
        _, var_lln = M.attention_log_moments(p_lln)
        qq_lln.append(M.lognormality_score(p_lln))
        match_errs.append(abs(float(var_lln) - var_sm) / var_sm)
        p_raw = mm.lln_attn_matrix(q, k, 1.0, 1.0)
        raw_ratio.append(float(M.attention_log_moments(p_raw)[1]) / var_sm)
        if verbose:
            print(f"  sigma={sig}: var_sm={var_sm:.3f} (theory {theory:.3f})"
                  f" var_lln={float(var_lln):.3f} raw_ratio="
                  f"{raw_ratio[-1]:.3f} alpha={float(alpha):.2f}")
    dt_us = (time.time() - t0) * 1e6 / len(sigmas)
    rows.append(("fig5a_var_sm_rel_err", dt_us, float(np.mean(rel_errs))))
    rows.append(("prop31_lognormality_sm_qq", dt_us, float(np.min(qq_sm))))
    rows.append(("prop41_lognormality_lln_qq", dt_us, float(np.min(qq_lln))))
    rows.append(("fig5b_matched_var_rel_err", dt_us,
                 float(np.mean(match_errs))))
    rows.append(("fig5b_unmatched_var_ratio", dt_us,
                 float(np.mean(raw_ratio))))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived:.4f}")
