"""Health-sentinel overhead: serving throughput with the sentinel on vs off.

The robustness layer folds a per-row state-health reduction
(``core/health.py:unhealthy_rows`` — non-finite / magnitude / calibration
checks over every cache leaf) into the continuous-batching ``segment_fn``.
Because the reduction is fused into the segment's existing jit (no extra
dispatch, no extra host sync), its cost must be a small fraction of the
decode math.  This benchmark measures that cost directly:

* **sentinel_on**  — ``make_pool_setup(..., health=HealthConfig())``, the
  serving default; and
* **sentinel_off** — ``make_pool_setup(..., health=None)``, which replaces
  the reduction with a constant all-healthy vector;

serve the SAME deterministic request stream through the real
``ContinuousBatcher`` and compare min-of-repeats wall clock.

Gate: overhead <= 2% of the sentinel-off throughput (the ISSUE acceptance
bar).  Writes ``BENCH_robustness.json`` at the repo root (schema:
benchmarks/README.md).  CPU-container numbers are only meaningful relative
to each other on the same host.

Usage:
    PYTHONPATH=src python -m benchmarks.bench_robustness [--smoke] \
        [--out PATH] [--repeats K]
"""
from __future__ import annotations

import argparse
import json
import os

import jax

from repro.configs.base import ArchConfig
from repro.core.health import HealthConfig
from repro.launch.batcher import ContinuousBatcher, synthetic_traffic
from repro.launch.mesh import compat_mesh
from repro.launch.steps import make_pool_setup

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_OUT = os.path.join(ROOT, "BENCH_robustness.json")
GATE_PCT = 2.0


def _cfg(impl: str, *, blk: int) -> ArchConfig:
    h = 4
    return ArchConfig(
        name=f"robustness-bench-{impl}", family="dense", n_layers=2,
        d_model=128, n_heads=h, n_kv_heads=h, d_ff=256, vocab=512,
        head_dim=32, attn_impl=impl, diag_block=blk, lln_chunk=blk,
        softmax_chunk=2 * blk,
        lln_fixed_ab=2.1 if impl != "softmax" else 0.0,
        compute_dtype="float32", param_dtype="float32", remat="none",
        tie_embeddings=True)


def bench_one(impl: str, *, slots, n_requests, prompt_len, gen_lens,
              segment, blk, repeats, mesh, verbose) -> dict:
    from repro.models import build_model
    cfg = _cfg(impl, blk=blk)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_len = prompt_len + max(gen_lens) + 1
    reqs = synthetic_traffic(n_requests, cfg.vocab, [prompt_len], gen_lens,
                             seed=3)
    useful = sum(rq.gen_len for rq in reqs)

    engines = {}
    for mode, health in (("sentinel_off", None),
                         ("sentinel_on", HealthConfig())):
        pool = make_pool_setup(cfg, mesh, slots=slots, max_len=max_len,
                               segment=segment, health=health)
        eng = ContinuousBatcher(pool, params)
        eng.warmup([prompt_len])
        eng.run(reqs)                      # warm the full stream's shapes
        engines[mode] = eng

    walls = {"sentinel_off": [], "sentinel_on": []}
    for it in range(repeats):
        order = (("sentinel_off", "sentinel_on") if it % 2 == 0
                 else ("sentinel_on", "sentinel_off"))
        for mode in order:
            stats = engines[mode].run(reqs)
            assert stats.completed_tokens == useful
            walls[mode].append(stats.wall_s)
    off_s = min(walls["sentinel_off"])
    on_s = min(walls["sentinel_on"])
    overhead_pct = (on_s - off_s) / off_s * 100.0
    row = {
        "name": impl,
        "traffic": {"requests": n_requests, "slots": slots,
                    "prompt_len": prompt_len, "gen_lens": gen_lens,
                    "segment": segment, "useful_tokens": useful},
        "tok_s": {"sentinel_off": useful / off_s,
                  "sentinel_on": useful / on_s},
        "wall_s": {"sentinel_off": off_s, "sentinel_on": on_s},
        "overhead_pct": overhead_pct,
        "gate_pct": GATE_PCT,
        "pass": overhead_pct <= GATE_PCT,
    }
    if verbose:
        t = row["tok_s"]
        print(f"  off {t['sentinel_off']:7.1f} tok/s -> on "
              f"{t['sentinel_on']:7.1f} tok/s  "
              f"overhead {overhead_pct:+.2f}% "
              f"({'PASS' if row['pass'] else 'FAIL'} <= {GATE_PCT}%)",
              flush=True)
    return row


def run(out_path: str = DEFAULT_OUT, smoke: bool = False,
        repeats: int = 3, verbose: bool = True) -> dict:
    if smoke:
        impls = ["lln_diag"]
        slots, n_requests, prompt_len, segment, blk = 2, 4, 16, 4, 16
        gen_lens = [3, 3, 9]
        repeats = 1
    else:
        impls = ["lln_diag", "softmax"]
        slots, n_requests, prompt_len, segment, blk = 4, 12, 16, 8, 16
        gen_lens = [9, 9, 33]
    mesh = compat_mesh((1, 1), ("data", "model"))
    rows = []
    with mesh:
        for impl in impls:
            if verbose:
                print(f"== {impl} ==", flush=True)
            rows.append(bench_one(impl, slots=slots, n_requests=n_requests,
                                  prompt_len=prompt_len, gen_lens=gen_lens,
                                  segment=segment, blk=blk, repeats=repeats,
                                  mesh=mesh, verbose=verbose))
    report = {
        "backend": jax.default_backend(),
        "interpret_mode": jax.default_backend() == "cpu",
        "repeats": repeats,
        "modes": {
            "sentinel_off": "make_pool_setup(health=None): segment_fn "
                            "returns a constant all-healthy row vector",
            "sentinel_on": "make_pool_setup(health=HealthConfig()): "
                           "per-row non-finite/magnitude/calibration "
                           "reduction fused into segment_fn's jit",
        },
        "gate": f"sentinel overhead <= {GATE_PCT}% of sentinel-off wall "
                "clock on every cell",
        "results": rows,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
        f.write("\n")
    if verbose:
        print(f"wrote {out_path}")
    return report


def run_rows(verbose: bool = True):
    """benchmarks/run.py adapter: (name, us_per_call, derived) CSV rows —
    us = sentinel-on wall time for the stream, derived = overhead fraction
    vs sentinel-off."""
    report = run(verbose=verbose)
    return [(f"robustness_{row['name']}",
             row["wall_s"]["sentinel_on"] * 1e6,
             row["overhead_pct"] / 100.0) for row in report["results"]]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--smoke", action="store_true", help="one tiny cell (CI)")
    args = ap.parse_args()
    run(args.out, smoke=args.smoke, repeats=args.repeats)


if __name__ == "__main__":
    main()
